"""Serving SLO benchmark — replicated vs sharded PosteriorCache, with the
sharded path measured in all three of its regimes.

Every lane is constructed from a ``repro.api.ServeConfig`` and served
through ``api.Server`` — the same front door the CLIs use — and each
lane's record in BENCH_serve.json embeds that exact config
(``serve_config``) plus the training ``fit_config``, so any row is
reproducible from the report alone:

  * replicated — ``ServeConfig(mode="replicated")``: the full cache on
    one device (the ``launch/serve.py --gp`` path);
  * sharded serial — ``ServeConfig(mode="sharded", pipeline="serial",
    q_max=<prepass>)``: the distributed endpoint run synchronously, one
    request at a time (the PR-2 measurement regime, on the rebuilt
    program), q_max from the whole-stream prepass
    (``serve_sharded.prepass_routing``);
  * sharded pipelined — ``pipeline="pipelined"``: the overlapped driver
    (batch t+1 routed on the host while the mesh evaluates batch t),
    q_max from the streaming high-water-mark policy
    (``routing.StreamingQMax``). Results bitwise identical to serial
    (checked);
  * sharded pipelined fused — ``backend="fused"``: the slot-stacked
    Pallas predict kernel. On CPU the kernel runs in INTERPRET mode
    (``ServeConfig.resolve_backend`` warns once), so its latency lane is
    informative only there (and runs a shortened stream); on TPU it is
    the production configuration — ``backend="auto"`` resolves to it
    there and to the XLA-compiled jnp lane everywhere else;
  * skew lanes (``--skew zipf``, the default) — a zipf-skewed query
    stream (``repro.data.spatial.zipf_query_stream``) served twice
    through the pipelined driver: ``router="single"`` (every device
    block pads to the hottest cell) vs ``router="two-level"`` (hot-cell
    overflow spills onto corner-cell neighbors). Reports p50/p99 and the
    padded-row waste of each, the waste-reduction ratio (the acceptance
    gate: >= 2x), the spill counts, plus the same equivalence gates —
    two-level vs replicated atol 1e-5, two-level pipelined bitwise ==
    serial.

Reports p50/p95/p99 request latency and points/s throughput per lane, the
sharded-vs-replicated allclose gate (atol 1e-5), pipelined-vs-serial
bitwise equality, per-device cache-factor memory (sharded must be ~1/P of
replicated), and the speedup of the rebuilt lanes over the committed PR-2
sharded baseline (p50 284.7 ms on the same 16x16 mesh). Default shapes
are the ROADMAP's 16x16 dry-run mesh — 256 VIRTUAL host devices
time-slicing this CPU, so sharded wall-clock is an upper bound; the
equivalence, memory, and report structure are the deliverable, the
absolute numbers become meaningful on a real mesh.

  PYTHONPATH=src python -m benchmarks.bench_serve           # emits BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.bench_serve --quick   # CI-sized (4x4 mesh)
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke   # seconds (3x3 mesh)
"""
from __future__ import annotations

import argparse
import json

import numpy as np

# The committed PR-2 sharded lane (BENCH_serve.json at commit b8b3a10,
# 16x16 mesh, serial, per-slot ppermute halo) — the regression baseline
# the rebuilt pipeline is gated against.
PR2_SHARDED_P50_MS = 284.726


def run(
    *,
    grid_side: int = 16,
    m: int = 8,
    n_train: int = 20_000,
    train_iters: int = 400,
    batch: int = 2048,
    requests: int = 32,
    fused_requests: int | None = None,
    skew: str = "zipf",
    skew_alpha: float = 1.1,
    out_path: str = "BENCH_serve.json",
) -> dict:
    # virtual devices must be forced before any jax computation
    from repro.launch import serve_sharded as ss

    ss.ensure_host_devices(grid_side * grid_side)

    import jax

    from repro import api

    on_tpu = jax.default_backend() == "tpu"
    if fused_requests is None:
        # interpret-mode Pallas (CPU) is a correctness lane, not a speed
        # lane — keep it short there; on TPU measure the full stream.
        fused_requests = requests if on_tpu else min(requests, 4)

    print(f"# bench_serve: grid={grid_side}x{grid_side} m={m} B={batch} "
          f"requests={requests} backend={jax.default_backend()}")
    # ONE shared recipe with the serving drivers, so the equivalence gate
    # compares the same posterior both paths serve. The allclose gate needs
    # a CONVERGED posterior (same reason as bench_predict: near init the
    # f32 variance path is a large cancellation on both sides).
    ds, fitted = ss.train_demo_surface(
        seed=0, n=n_train, grid_side=grid_side, m=m, train_iters=train_iters,
    )
    grid = fitted.grid

    rng = np.random.default_rng(1)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    batches = [
        rng.uniform(lo, hi, (batch, 2)).astype(np.float32) for _ in range(requests)
    ]

    # ---- replicated lane --------------------------------------------------
    cfg_rep = api.ServeConfig(mode="replicated")
    srv_rep = api.Server(fitted, cfg_rep)
    m_rep, v_rep = srv_rep.submit(batches[0])  # warm + the equivalence target
    rec_rep = srv_rep.stream(batches, warm=False)

    # ---- sharded serial lane (whole-stream prepass q_max) -----------------
    # fixed_q_max: only the budget crosses into the ServeConfig — the
    # Server's route stage re-bins each batch itself (one numpy bincount
    # per request, microseconds against the tens-of-ms device window);
    # that re-bin is the price of the uniform front door.
    q_max = ss.fixed_q_max(grid, batches)
    cfg_serial = api.ServeConfig(
        mode="sharded", pipeline="serial", router="single",
        backend="ref", q_max=q_max,
    )
    srv_serial = api.Server(fitted, cfg_serial)
    total_b, device_b = srv_serial.cache_bytes
    m_sh, v_sh = srv_serial.submit(batches[0])  # warmup/compile + gate
    mean_err = float(np.abs(m_sh - m_rep).max())
    var_err = float(np.abs(v_sh - v_rep).max())

    serial_results: dict = {}
    rec_serial = srv_serial.stream(
        batches, warm=False,
        on_result=lambda i, out: serial_results.setdefault(i, out),
    )

    # ---- sharded pipelined lane (streaming q_max) -------------------------
    cfg_pipe = api.ServeConfig(
        mode="sharded", pipeline="pipelined", router="single", backend="ref",
    )
    srv_pipe = api.Server(fitted, cfg_pipe)
    pipe_results: dict = {}
    rec_pipe = srv_pipe.stream(
        batches, warm=True,
        on_result=lambda i, out: pipe_results.setdefault(i, out),
    )
    bitwise = all(
        np.array_equal(pipe_results[i][0], serial_results[i][0])
        and np.array_equal(pipe_results[i][1], serial_results[i][1])
        for i in range(len(batches))
    )

    # ---- fused-kernel lane (slot-stacked Pallas predict) ------------------
    cfg_fused = api.ServeConfig(
        mode="sharded", pipeline="pipelined", router="single", backend="fused",
    )
    srv_fused = api.Server(fitted, cfg_fused)  # warns once: interpret on CPU
    fused_stream = batches[:fused_requests]
    m_fu, v_fu = srv_fused.submit(batches[0])  # warm + compare
    fused_mean_err = float(np.abs(m_fu - serial_results[0][0]).max())
    fused_var_err = float(np.abs(v_fu - serial_results[0][1]).max())
    rec_fused = srv_fused.stream(fused_stream, warm=False)

    # ---- skew lanes: single-level vs two-level router under zipf ---------
    skew_rec = None
    if skew == "zipf":
        from repro.data.spatial import zipf_query_stream

        zbatches = zipf_query_stream(
            grid, batch, requests, alpha=skew_alpha, seed=7
        )

        def skew_lane(router: str):
            """One pipelined pass over the zipf stream. The warm pass
            compiles through the same stages, so the server's table
            counters are zeroed after warmup and the stats cover the
            measured stream exactly once."""
            cfg = api.ServeConfig(
                mode="sharded", pipeline="pipelined", router=router,
                backend="ref",
            )
            srv = api.Server(fitted, cfg)
            srv.submit(zbatches[0])  # warm/compile
            srv.reset_stats()
            results: dict = {}
            rec = srv.stream(
                zbatches, warm=False,
                on_result=lambda i, out: results.setdefault(i, out),
            )
            return cfg, rec, srv.stats(), results

        cfg_z1, rec_z1, stat_z1, res_z1 = skew_lane("single")
        cfg_z2, rec_z2, stat_z2, res_z2 = skew_lane("two-level")

        # the routers place queries differently, so only scatter-level
        # equality is meaningful: identical answers per request position
        z_router_err = max(
            float(np.abs(res_z2[i][j] - res_z1[i][j]).max())
            for i in range(len(zbatches)) for j in (0, 1)
        )
        # two-level vs replicated on the first skewed batch
        mz, vz = res_z2[0]
        mz_rep, vz_rep = srv_rep.submit(zbatches[0])
        z_mean_err = float(np.abs(mz - mz_rep).max())
        z_var_err = float(np.abs(vz - vz_rep).max())
        # two-level pipelined bitwise == two-level serial (fresh policy ->
        # identical q_max trajectory)
        srv_zs = api.Server(fitted, api.ServeConfig(
            mode="sharded", pipeline="serial", router="two-level",
            backend="ref",
        ))
        z_bitwise = all(
            np.array_equal(out[j], res_z2[i][j])
            for i, out in enumerate(srv_zs.submit(b) for b in zbatches)
            for j in (0, 1)
        )
        skew_rec = {
            "alpha": skew_alpha,
            "requests": len(zbatches),
            # the lane-level "spilled" counts the MEASURED stream only (the
            # policy's own cumulative total also includes the warm batch,
            # so it is dropped from the nested record — one number per fact)
            "single_level": {
                **rec_z1["latency_ms"],
                "points_per_s": rec_z1["points_per_s"],
                "waste_rows": stat_z1["waste_rows"],
                "spilled": stat_z1["spilled"],
                "qmax_policy": stat_z1["qmax_policy"],
                "serve_config": cfg_z1.to_dict(),
            },
            "two_level": {
                **rec_z2["latency_ms"],
                "points_per_s": rec_z2["points_per_s"],
                "waste_rows": stat_z2["waste_rows"],
                "spilled": stat_z2["spilled"],
                "qmax_policy": {
                    k: v for k, v in stat_z2["qmax_policy"].items()
                    if k != "spilled"
                },
                "serve_config": cfg_z2.to_dict(),
            },
            "waste_reduction_vs_single": (
                stat_z1["waste_rows"] / max(stat_z2["waste_rows"], 1)
            ),
            "equivalence": {
                "two_level_vs_single_max_abs_err": z_router_err,
                "max_abs_err_mean_vs_replicated": z_mean_err,
                "max_abs_err_var_vs_replicated": z_var_err,
                "atol_1e5_ok": bool(z_mean_err <= 1e-5 and z_var_err <= 1e-5),
                "pipelined_bitwise_serial": bool(z_bitwise),
            },
        }

    rec = {
        "P": grid.num_partitions,
        "m": m,
        "grid": f"{grid_side}x{grid_side}",
        "mesh_devices": srv_serial.mesh.size,
        "backend": jax.default_backend(),
        "batch": batch,
        "requests": requests,
        "fit_config": fitted.config.to_dict(),
        "replicated": {
            **rec_rep["latency_ms"],
            "points_per_s": rec_rep["points_per_s"],
            "cache_bytes_per_device": total_b,
            "serve_config": cfg_rep.to_dict(),
        },
        "sharded_serial": {
            **rec_serial["latency_ms"],
            "points_per_s": rec_serial["points_per_s"],
            "q_max": q_max,
            "cache_bytes_per_device": device_b,
            "cache_shard_ratio": total_b / max(device_b, 1),
            "serve_config": cfg_serial.to_dict(),
        },
        "sharded_pipelined": {
            **rec_pipe["latency_ms"],
            "points_per_s": rec_pipe["points_per_s"],
            "qmax_policy": rec_pipe["qmax_policy"],
            "serve_config": cfg_pipe.to_dict(),
        },
        "sharded_pipelined_fused": {
            **rec_fused["latency_ms"],
            "points_per_s": rec_fused["points_per_s"],
            "requests": len(fused_stream),
            "interpret": not on_tpu,
            "serve_config": cfg_fused.to_dict(),
        },
        "equivalence": {
            "max_abs_err_mean": mean_err,
            "max_abs_err_var": var_err,
            "atol_1e5_ok": bool(mean_err <= 1e-5 and var_err <= 1e-5),
            "pipelined_bitwise_serial": bool(bitwise),
            "fused_vs_jnp_max_abs_err_mean": fused_mean_err,
            "fused_vs_jnp_max_abs_err_var": fused_var_err,
        },
        "speedup": {
            "pipelined_vs_serial_p50": (
                rec_serial["latency_ms"]["p50_ms"] / rec_pipe["latency_ms"]["p50_ms"]
            ),
        },
        "skew": skew_rec,
    }
    if grid_side == 16 and m == 8 and batch == 2048:
        # the PR-2 baseline was recorded on exactly this configuration —
        # a cross-shape ratio (--quick/--smoke) would be meaningless
        rec["baseline"] = {"pr2_sharded_p50_ms": PR2_SHARDED_P50_MS}
        rec["speedup"]["serial_vs_pr2_p50"] = (
            PR2_SHARDED_P50_MS / rec_serial["latency_ms"]["p50_ms"]
        )
        rec["speedup"]["pipelined_vs_pr2_p50"] = (
            PR2_SHARDED_P50_MS / rec_pipe["latency_ms"]["p50_ms"]
        )
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {out_path}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized shapes (4x4 mesh)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale shapes (3x3 mesh) — the regression "
                         "smoke lane (make bench-serve-smoke)")
    ap.add_argument("--skew", choices=("zipf", "none"), default="zipf",
                    help="also serve a zipf-skewed stream through the "
                         "single-level AND two-level routers, reporting "
                         "padded-row waste and p50/p99 per router "
                         "(default: zipf)")
    ap.add_argument("--skew-alpha", type=float, default=1.1,
                    help="zipf exponent of the skewed stream's cell "
                         "popularity (higher = hotter hot cells)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        run(grid_side=3, m=5, n_train=1200, train_iters=150, batch=128,
            requests=6, fused_requests=2, skew=args.skew,
            skew_alpha=args.skew_alpha, out_path=args.out)
    elif args.quick:
        run(grid_side=4, m=6, n_train=4000, train_iters=200, batch=512,
            requests=10, skew=args.skew, skew_alpha=args.skew_alpha,
            out_path=args.out)
    else:
        run(skew=args.skew, skew_alpha=args.skew_alpha, out_path=args.out)


if __name__ == "__main__":
    main()
