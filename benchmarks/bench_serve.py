"""Serving SLO benchmark — replicated vs sharded PosteriorCache, with the
sharded path measured in all three of its regimes:

  * replicated — ``blend.predict_blended`` against the full cache on one
    device (the ``launch/serve.py --gp`` path);
  * sharded serial — the distributed endpoint of ``launch/serve_sharded``
    run synchronously: route, halo-stack, transfer + evaluate, scatter,
    one request at a time (the PR-2 measurement regime, on the rebuilt
    program). q_max comes from the whole-stream prepass
    (``prepass_routing``), whose binning the table build REUSES;
  * sharded pipelined — the overlapped driver
    (``pipelined_request_loop``): batch t+1 is routed on the host while
    the mesh evaluates batch t, q_max follows the streaming
    high-water-mark policy (``routing.StreamingQMax``), and the loop only
    blocks when a result is consumed. Results are bitwise identical to
    serial (checked);
  * sharded pipelined fused — same, with the slot-stacked Pallas predict
    kernel (``use_pallas=True``). On CPU the kernel runs in INTERPRET
    mode, so its latency lane is informative only there (and runs a
    shortened stream); on TPU it is the production configuration;
  * skew lanes (``--skew zipf``, the default) — a zipf-skewed query
    stream (``repro.data.spatial.zipf_query_stream``) served twice
    through the pipelined driver: once with the single-level
    ``StreamingQMax`` router (every device block pads to the hottest
    cell) and once with the two-level ``TwoLevelQMax`` router (hot-cell
    overflow spills onto corner-cell neighbors). Reports p50/p99 and the
    padded-row waste of each, the waste-reduction ratio (the acceptance
    gate: >= 2x), the spill counts, plus the same equivalence gates —
    two-level vs replicated atol 1e-5, two-level pipelined bitwise ==
    serial.

Reports p50/p95/p99 request latency and points/s throughput per lane, the
sharded-vs-replicated allclose gate (atol 1e-5), pipelined-vs-serial
bitwise equality, per-device cache-factor memory (sharded must be ~1/P of
replicated), and the speedup of the rebuilt lanes over the committed PR-2
sharded baseline (p50 284.7 ms on the same 16x16 mesh). Default shapes
are the ROADMAP's 16x16 dry-run mesh — 256 VIRTUAL host devices
time-slicing this CPU, so sharded wall-clock is an upper bound; the
equivalence, memory, and report structure are the deliverable, the
absolute numbers become meaningful on a real mesh.

  PYTHONPATH=src python -m benchmarks.bench_serve           # emits BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.bench_serve --quick   # CI-sized (4x4 mesh)
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke   # seconds (3x3 mesh)
"""
from __future__ import annotations

import argparse
import json

import numpy as np

# The committed PR-2 sharded lane (BENCH_serve.json at commit b8b3a10,
# 16x16 mesh, serial, per-slot ppermute halo) — the regression baseline
# the rebuilt pipeline is gated against.
PR2_SHARDED_P50_MS = 284.726


def run(
    *,
    grid_side: int = 16,
    m: int = 8,
    n_train: int = 20_000,
    train_iters: int = 400,
    batch: int = 2048,
    requests: int = 32,
    fused_requests: int | None = None,
    skew: str = "zipf",
    skew_alpha: float = 1.1,
    out_path: str = "BENCH_serve.json",
) -> dict:
    # virtual devices must be forced before any jax computation
    from repro.launch import serve_sharded as ss

    ss.ensure_host_devices(grid_side * grid_side)

    import jax
    import jax.numpy as jnp

    from repro.core import psvgp, routing
    from repro.core.blend import predict_blended

    on_tpu = jax.default_backend() == "tpu"
    if fused_requests is None:
        # interpret-mode Pallas (CPU) is a correctness lane, not a speed
        # lane — keep it short there; on TPU measure the full stream.
        fused_requests = requests if on_tpu else min(requests, 4)

    print(f"# bench_serve: grid={grid_side}x{grid_side} m={m} B={batch} "
          f"requests={requests} backend={jax.default_backend()}")
    # ONE shared recipe with the serving drivers, so the equivalence gate
    # compares the same posterior both paths serve. The allclose gate needs
    # a CONVERGED posterior (same reason as bench_predict: near init the
    # f32 variance path is a large cancellation on both sides).
    ds, grid, data, static, state = ss.train_demo_surface(
        seed=0, n=n_train, grid_side=grid_side, m=m, train_iters=train_iters,
    )
    cache = psvgp.posterior_cache(static, state)
    jax.block_until_ready(cache)

    rng = np.random.default_rng(1)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    batches = [
        rng.uniform(lo, hi, (batch, 2)).astype(np.float32) for _ in range(requests)
    ]

    # ---- replicated lane --------------------------------------------------
    def rep_answer(q):
        out = predict_blended(static, state, grid, jnp.asarray(q), cache=cache)
        jax.block_until_ready(out)
        return out

    pct_rep, qps_rep = ss.timed_request_loop(rep_answer, batches)

    # ---- sharded setup ----------------------------------------------------
    mesh = ss.mesh_for_grid(grid)
    cache_sh = ss.shard_cache(cache, mesh)
    jax.block_until_ready(cache_sh)
    total_b, device_b = ss.cache_memory_bytes(cache_sh)
    blend_fn = ss.make_sharded_blend(
        mesh, mesh.axis_names, grid, static.cov_fn, cache_sh
    )

    # ---- sharded serial lane (whole-stream prepass q_max) -----------------
    q_max, cells = ss.prepass_routing(grid, batches)
    stacker = routing.make_halo_stacker(grid)

    serial_results = []
    idx = {"i": 0}

    def sh_answer(q):
        i = idx["i"] % len(batches)
        idx["i"] += 1
        table = routing.build_routing_table(grid, q, q_max=q_max, cells=cells[i])
        mean, var = blend_fn(
            cache_sh, stacker(table.xq), table.corner_slot, table.corner_w
        )
        jax.block_until_ready((mean, var))
        return (
            routing.scatter_results(table, np.asarray(mean)),
            routing.scatter_results(table, np.asarray(var)),
        )

    m_sh, v_sh = sh_answer(batches[0])  # warmup / compile + equivalence gate
    idx["i"] = 0
    m_rep, v_rep = rep_answer(batches[0])
    mean_err = float(np.abs(m_sh - np.asarray(m_rep)).max())
    var_err = float(np.abs(v_sh - np.asarray(v_rep)).max())

    def sh_answer_keep(q):
        out = sh_answer(q)
        serial_results.append(out)
        return out

    # the equivalence check above already compiled + warmed the program
    pct_serial, qps_serial = ss.timed_request_loop(sh_answer_keep, batches, warm=False)

    # ---- sharded pipelined lane (streaming q_max) -------------------------
    policy = routing.StreamingQMax()
    route, submit, collect = ss.make_request_stages(
        grid, blend_fn, cache_sh, policy=policy
    )
    pipe_results = {}
    pct_pipe, qps_pipe = ss.pipelined_request_loop(
        route, submit, collect, batches,
        warm=True, on_result=lambda i, out: pipe_results.setdefault(i, out),
    )
    bitwise = all(
        np.array_equal(pipe_results[i][0], serial_results[i][0])
        and np.array_equal(pipe_results[i][1], serial_results[i][1])
        for i in range(len(batches))
    )

    # ---- fused-kernel lane (slot-stacked Pallas predict) ------------------
    blend_fused = ss.make_sharded_blend(
        mesh, mesh.axis_names, grid, static.cov_fn, cache_sh, use_pallas=True
    )
    policy_f = routing.StreamingQMax()
    route_f, submit_f, collect_f = ss.make_request_stages(
        grid, blend_fused, cache_sh, policy=policy_f
    )
    fused_stream = batches[:fused_requests]
    m_fu, v_fu = collect_f(submit_f(route_f(batches[0])))  # warm + compare
    fused_mean_err = float(np.abs(m_fu - serial_results[0][0]).max())
    fused_var_err = float(np.abs(v_fu - serial_results[0][1]).max())
    pct_fused, qps_fused = ss.pipelined_request_loop(
        route_f, submit_f, collect_f, fused_stream, warm=False
    )

    # ---- skew lanes: single-level vs two-level router under zipf ---------
    skew_rec = None
    if skew == "zipf":
        from repro.data.spatial import zipf_query_stream

        zbatches = zipf_query_stream(
            grid, batch, requests, alpha=skew_alpha, seed=7
        )

        def instrumented_stages(policy):
            """Pipeline stages + per-table waste/spill accounting. The
            warm pass compiles through the same stages, so counters are
            zeroed after warmup and the stats cover the measured stream
            exactly once."""
            route0, submit0, collect0 = ss.make_request_stages(
                grid, blend_fn, cache_sh, policy=policy
            )
            stat = {"waste_rows": 0, "spilled": 0}

            def route(q):
                table, blocks = route0(q)
                stat["waste_rows"] += table.waste_rows()
                stat["spilled"] += table.num_spilled()
                return table, blocks

            return route, submit0, collect0, stat

        def skew_lane(policy):
            route, submit, collect, stat = instrumented_stages(policy)
            results = {}
            collect(submit(route(zbatches[0])))  # warm/compile
            stat.update(waste_rows=0, spilled=0)
            pct, qps = ss.pipelined_request_loop(
                route, submit, collect, zbatches, warm=False,
                on_result=lambda i, out: results.setdefault(i, out),
            )
            return pct, qps, stat, results

        pol_z1 = routing.StreamingQMax()
        pct_z1, qps_z1, stat_z1, res_z1 = skew_lane(pol_z1)
        pol_z2 = routing.TwoLevelQMax()
        pct_z2, qps_z2, stat_z2, res_z2 = skew_lane(pol_z2)

        # the routers place queries differently, so only scatter-level
        # equality is meaningful: identical answers per request position
        z_router_err = max(
            float(np.abs(res_z2[i][j] - res_z1[i][j]).max())
            for i in range(len(zbatches)) for j in (0, 1)
        )
        # two-level vs replicated on the first skewed batch
        mz, vz = res_z2[0]
        mz_rep, vz_rep = predict_blended(
            static, state, grid, jnp.asarray(zbatches[0]), cache=cache
        )
        z_mean_err = float(np.abs(mz - np.asarray(mz_rep)).max())
        z_var_err = float(np.abs(vz - np.asarray(vz_rep)).max())
        # two-level pipelined bitwise == two-level serial (fresh policy ->
        # identical q_max trajectory)
        route_zs, submit_zs, collect_zs = ss.make_request_stages(
            grid, blend_fn, cache_sh, policy=routing.TwoLevelQMax()
        )
        z_bitwise = all(
            np.array_equal(out[j], res_z2[i][j])
            for i, out in enumerate(
                collect_zs(submit_zs(route_zs(b))) for b in zbatches
            )
            for j in (0, 1)
        )
        skew_rec = {
            "alpha": skew_alpha,
            "requests": len(zbatches),
            # the lane-level "spilled" counts the MEASURED stream only (the
            # policy's own cumulative total also includes the warm batch,
            # so it is dropped from the nested record — one number per fact)
            "single_level": {
                **pct_z1, "points_per_s": qps_z1, **stat_z1,
                "qmax_policy": pol_z1.stats(),
            },
            "two_level": {
                **pct_z2, "points_per_s": qps_z2, **stat_z2,
                "qmax_policy": {
                    k: v for k, v in pol_z2.stats().items() if k != "spilled"
                },
            },
            "waste_reduction_vs_single": (
                stat_z1["waste_rows"] / max(stat_z2["waste_rows"], 1)
            ),
            "equivalence": {
                "two_level_vs_single_max_abs_err": z_router_err,
                "max_abs_err_mean_vs_replicated": z_mean_err,
                "max_abs_err_var_vs_replicated": z_var_err,
                "atol_1e5_ok": bool(z_mean_err <= 1e-5 and z_var_err <= 1e-5),
                "pipelined_bitwise_serial": bool(z_bitwise),
            },
        }

    rec = {
        "P": grid.num_partitions,
        "m": m,
        "grid": f"{grid_side}x{grid_side}",
        "mesh_devices": mesh.size,
        "backend": jax.default_backend(),
        "batch": batch,
        "requests": requests,
        "replicated": {
            **pct_rep,
            "points_per_s": qps_rep,
            "cache_bytes_per_device": total_b,
        },
        "sharded_serial": {
            **pct_serial,
            "points_per_s": qps_serial,
            "q_max": q_max,
            "cache_bytes_per_device": device_b,
            "cache_shard_ratio": total_b / max(device_b, 1),
        },
        "sharded_pipelined": {
            **pct_pipe,
            "points_per_s": qps_pipe,
            "qmax_policy": policy.stats(),
        },
        "sharded_pipelined_fused": {
            **pct_fused,
            "points_per_s": qps_fused,
            "requests": len(fused_stream),
            "interpret": not on_tpu,
        },
        "equivalence": {
            "max_abs_err_mean": mean_err,
            "max_abs_err_var": var_err,
            "atol_1e5_ok": bool(mean_err <= 1e-5 and var_err <= 1e-5),
            "pipelined_bitwise_serial": bool(bitwise),
            "fused_vs_jnp_max_abs_err_mean": fused_mean_err,
            "fused_vs_jnp_max_abs_err_var": fused_var_err,
        },
        "speedup": {
            "pipelined_vs_serial_p50": pct_serial["p50_ms"] / pct_pipe["p50_ms"],
        },
        "skew": skew_rec,
    }
    if grid_side == 16 and m == 8 and batch == 2048:
        # the PR-2 baseline was recorded on exactly this configuration —
        # a cross-shape ratio (--quick/--smoke) would be meaningless
        rec["baseline"] = {"pr2_sharded_p50_ms": PR2_SHARDED_P50_MS}
        rec["speedup"]["serial_vs_pr2_p50"] = PR2_SHARDED_P50_MS / pct_serial["p50_ms"]
        rec["speedup"]["pipelined_vs_pr2_p50"] = PR2_SHARDED_P50_MS / pct_pipe["p50_ms"]
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {out_path}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized shapes (4x4 mesh)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale shapes (3x3 mesh) — the regression "
                         "smoke lane (make bench-serve-smoke)")
    ap.add_argument("--skew", choices=("zipf", "none"), default="zipf",
                    help="also serve a zipf-skewed stream through the "
                         "single-level AND two-level routers, reporting "
                         "padded-row waste and p50/p99 per router "
                         "(default: zipf)")
    ap.add_argument("--skew-alpha", type=float, default=1.1,
                    help="zipf exponent of the skewed stream's cell "
                         "popularity (higher = hotter hot cells)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        run(grid_side=3, m=5, n_train=1200, train_iters=150, batch=128,
            requests=6, fused_requests=2, skew=args.skew,
            skew_alpha=args.skew_alpha, out_path=args.out)
    elif args.quick:
        run(grid_side=4, m=6, n_train=4000, train_iters=200, batch=512,
            requests=10, skew=args.skew, skew_alpha=args.skew_alpha,
            out_path=args.out)
    else:
        run(skew=args.skew, skew_alpha=args.skew_alpha, out_path=args.out)


if __name__ == "__main__":
    main()
