"""Serving SLO benchmark — replicated vs sharded PosteriorCache.

Trains one PSVGP on the synthetic E3SM-like field, then serves the same
request stream twice:

  * replicated — ``blend.predict_blended`` against the full cache on one
    device (the ``launch/serve.py --gp`` path);
  * sharded — the distributed endpoint of ``launch/serve_sharded``: cache
    factors one-partition-per-device over a gy x gx mesh, queries routed by
    ``core/routing``, corners resolved with the 1-hop ppermute halo.
    Sharded latency INCLUDES host-side routing + result scatter.

Reports p50/p95/p99 request latency and points/s throughput for both
paths, the sharded-vs-replicated allclose gate (atol 1e-5), and per-device
cache-factor memory (sharded must be ~1/P of replicated). Default shapes
are the ROADMAP's 16x16 dry-run mesh — 256 VIRTUAL host devices
time-slicing this CPU, so sharded wall-clock is an upper bound (every
"device" shares one socket); the equivalence, memory, and report structure
are the deliverable, the absolute numbers become meaningful on a real
mesh.

  PYTHONPATH=src python -m benchmarks.bench_serve           # emits BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.bench_serve --quick   # CI-sized (4x4 mesh)
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def run(
    *,
    grid_side: int = 16,
    m: int = 8,
    n_train: int = 20_000,
    train_iters: int = 400,
    batch: int = 2048,
    requests: int = 32,
    out_path: str = "BENCH_serve.json",
) -> dict:
    # virtual devices must be forced before any jax computation
    from repro.launch import serve_sharded as ss

    ss.ensure_host_devices(grid_side * grid_side)

    import jax
    import jax.numpy as jnp

    from repro.core import psvgp, routing
    from repro.core.blend import predict_blended

    print(f"# bench_serve: grid={grid_side}x{grid_side} m={m} B={batch} "
          f"requests={requests} backend={jax.default_backend()}")
    # ONE shared recipe with the serving drivers, so the equivalence gate
    # compares the same posterior both paths serve. The allclose gate needs
    # a CONVERGED posterior (same reason as bench_predict: near init the
    # f32 variance path is a large cancellation on both sides).
    ds, grid, data, static, state = ss.train_demo_surface(
        seed=0, n=n_train, grid_side=grid_side, m=m, train_iters=train_iters,
    )
    cache = psvgp.posterior_cache(static, state)
    jax.block_until_ready(cache)

    rng = np.random.default_rng(1)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    batches = [
        rng.uniform(lo, hi, (batch, 2)).astype(np.float32) for _ in range(requests)
    ]

    # ---- replicated path --------------------------------------------------
    def rep_answer(q):
        out = predict_blended(static, state, grid, jnp.asarray(q), cache=cache)
        jax.block_until_ready(out)
        return out

    pct_rep, qps_rep = ss.timed_request_loop(rep_answer, batches)

    # ---- sharded path -----------------------------------------------------
    mesh = ss.mesh_for_grid(grid)
    cache_sh = ss.shard_cache(cache, mesh)
    jax.block_until_ready(cache_sh)
    total_b, device_b = ss.cache_memory_bytes(cache_sh)
    blend_fn = ss.make_sharded_blend(
        mesh, mesh.axis_names, grid, static.cov_fn, cache_sh,
        use_pallas=(jax.default_backend() == "tpu"),
    )
    q_max = ss.fixed_q_max(grid, batches)

    def sh_answer(q):
        table = routing.build_routing_table(grid, q, q_max=q_max)
        xq, cs, cw = ss.shard_table(table, mesh)
        mean, var = blend_fn(cache_sh, xq, cs, cw)
        jax.block_until_ready((mean, var))
        return (
            routing.scatter_results(table, np.asarray(mean)),
            routing.scatter_results(table, np.asarray(var)),
        )

    m_sh, v_sh = sh_answer(batches[0])  # warmup / compile + equivalence gate
    m_rep, v_rep = rep_answer(batches[0])
    mean_err = float(np.abs(m_sh - np.asarray(m_rep)).max())
    var_err = float(np.abs(v_sh - np.asarray(v_rep)).max())

    # equivalence check above already compiled + warmed the sharded path
    pct_sh, qps_sh = ss.timed_request_loop(sh_answer, batches, warm=False)

    rec = {
        "P": grid.num_partitions,
        "m": m,
        "grid": f"{grid_side}x{grid_side}",
        "mesh_devices": mesh.size,
        "backend": jax.default_backend(),
        "batch": batch,
        "requests": requests,
        "q_max": q_max,
        "replicated": {
            **pct_rep,
            "points_per_s": qps_rep,
            "cache_bytes_per_device": total_b,
        },
        "sharded": {
            **pct_sh,
            "points_per_s": qps_sh,
            "cache_bytes_per_device": device_b,
            "cache_shard_ratio": total_b / max(device_b, 1),
        },
        "equivalence": {
            "max_abs_err_mean": mean_err,
            "max_abs_err_var": var_err,
            "atol_1e5_ok": bool(mean_err <= 1e-5 and var_err <= 1e-5),
        },
    }
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {out_path}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized shapes (4x4 mesh)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.quick:
        run(grid_side=4, m=6, n_train=4000, train_iters=200, batch=512,
            requests=10, out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
