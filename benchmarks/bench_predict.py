"""Cached-posterior prediction benchmark — the serving-path speedup.

Compares the seed implementation of ``blend.predict_blended`` (a full
O(m^3) Cholesky per query point per corner model, reproduced inline below
as the baseline) against the PosteriorCache path (factorize the P local
posteriors once, then O(m^2) per point per corner against cached factors).

Acceptance gate (ISSUE 1): at the paper's P=400 / m=25 scale with N=10k
queries on CPU, the cached path must be >= 5x faster end-to-end (cache
build INCLUDED), and cached predictions must match the uncached math to
atol 1e-5.

  PYTHONPATH=src python -m benchmarks.bench_predict           # emits BENCH_predict.json
  PYTHONPATH=src python -m benchmarks.bench_predict --quick   # CI-sized shapes
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psvgp, svgp
from repro.core.blend import corner_ids_weights, predict_blended
from repro.core.partition import make_grid, partition_data
from repro.data.spatial import e3sm_like_field


def _predict_blended_seed(static, state, grid, points) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The seed implementation, verbatim: per-point svgp.predict closure —
    one Kmm Cholesky per point per corner (the baseline being replaced)."""
    pts = np.asarray(points, np.float32)
    ids, w = corner_ids_weights(grid, pts)
    ids = jnp.asarray(ids)
    w = jnp.asarray(w)
    scfg = static.cfg.svgp

    def eval_corner(c):
        params_c = jax.tree.map(lambda a: jnp.take(a, ids[:, c], axis=0), state.params)

        def one(params, x):
            mean, var = svgp.predict(
                params, static.cov_fn, x[None], jitter=scfg.jitter, whitened=scfg.whitened
            )
            return mean[0], var[0]

        return jax.vmap(one)(params_c, jnp.asarray(pts))

    means, varis = zip(*(eval_corner(c) for c in range(4)), strict=True)
    means = jnp.stack(means, axis=1)  # (N, 4)
    varis = jnp.stack(varis, axis=1)
    mean = jnp.sum(w * means, axis=1)
    second = jnp.sum(w * (varis + means**2), axis=1)
    var = jnp.maximum(second - mean**2, 1e-12)
    return mean, var


def _time(fn, repeats: int) -> float:
    out = fn()  # warmup / compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(repeats):
        out = fn()
    jax.block_until_ready(out)
    return (time.time() - t0) / repeats


def run(
    *,
    P_side: int = 20,
    m: int = 25,
    n_queries: int = 10_000,
    n_train: int = 40_000,
    train_iters: int = 600,
    repeats: int = 3,
    out_path: str = "BENCH_predict.json",
) -> dict:
    print(f"# bench_predict: P={P_side * P_side} m={m} N={n_queries} "
          f"backend={jax.default_backend()}")
    ds = e3sm_like_field(n=n_train, seed=0)
    grid = make_grid(ds.x, P_side, P_side)
    data = partition_data(ds.x, ds.y, grid)
    cfg = psvgp.PSVGPConfig(
        svgp=svgp.SVGPConfig(num_inducing=m, input_dim=2),
        delta=0.25, batch_size=16, learning_rate=0.05,
    )
    static = psvgp.build(cfg, data)
    state = psvgp.init(jax.random.PRNGKey(0), cfg, data)
    state = psvgp.fit(static, state, data, train_iters)  # timings are
    # parameter-value independent, but the atol gate needs a CONVERGED
    # posterior: near init S ~ I and the f32 variance terms are large
    # differences of large numbers on both paths (measured: var err 2e-3 at
    # 10 iters vs 2e-6 at 800 — against q_f AND the f64 oracle alike)

    rng = np.random.default_rng(1)
    lo, hi = ds.x.min(axis=0), ds.x.max(axis=0)
    queries = jnp.asarray(rng.uniform(lo, hi, (n_queries, 2)).astype(np.float32))

    # --- correctness gate: predict_cached vs the uncached solve-based math
    # (svgp.q_f — the training-path marginal, never touches the cache) ---
    from repro.core import posterior

    # Each model is probed at ITS OWN partition's points — the region the
    # blend actually queries it in. (Probing model j at the far corner of
    # the domain inflates the f32 variance terms identically on both
    # paths; serving never asks that question.)
    scfg = static.cfg.svgp
    cache0 = psvgp.posterior_cache(static, state)
    mean_c, var_c = jax.vmap(
        lambda ca, xq: posterior.predict_cached(ca, static.cov_fn, xq)
    )(cache0, data.x)
    mean_u, var_u = jax.vmap(
        lambda p, xq: svgp.q_f(p, static.cov_fn, xq, scfg.jitter, scfg.whitened)
    )(state.params, data.x)
    mean_err = float(jnp.max(jnp.abs(mean_c - mean_u)))
    var_err = float(jnp.max(jnp.abs(var_c - var_u)))

    # blended surface: cached rewrite vs the seed per-point implementation
    mean_seed, var_seed = _predict_blended_seed(static, state, grid, queries)
    mean_new, var_new = predict_blended(static, state, grid, queries)
    blend_mean_err = float(jnp.max(jnp.abs(mean_new - mean_seed)))
    blend_var_err = float(jnp.max(jnp.abs(var_new - var_seed)))

    # --- timing: end-to-end (cache build INCLUDED in the cached path) ---
    t_seed = _time(lambda: _predict_blended_seed(static, state, grid, queries), repeats)
    t_cached = _time(lambda: predict_blended(static, state, grid, queries), repeats)
    # and the serving steady state: cache amortized across requests
    cache = psvgp.posterior_cache(static, state)
    jax.block_until_ready(cache)
    t_warm = _time(lambda: predict_blended(static, state, grid, queries, cache=cache), repeats)
    t_cache_build = _time(lambda: psvgp.posterior_cache(static, state), repeats)

    rec = {
        "P": P_side * P_side,
        "m": m,
        "n_queries": n_queries,
        "backend": jax.default_backend(),
        "seed_path_s": t_seed,
        "cached_path_s": t_cached,
        "cached_path_warm_s": t_warm,
        "cache_build_s": t_cache_build,
        "speedup_end_to_end": t_seed / t_cached,
        "speedup_warm": t_seed / t_warm,
        "queries_per_s_warm": n_queries / t_warm,
        "max_abs_err_mean": mean_err,
        "max_abs_err_var": var_err,
        "blend_max_abs_err_mean": blend_mean_err,
        "blend_max_abs_err_var": blend_var_err,
        "atol_1e5_ok": bool(mean_err <= 1e-5 and var_err <= 1e-5),
        "speedup_5x_ok": bool(t_seed / t_cached >= 5.0),
    }
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"wrote {out_path}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI-sized shapes")
    ap.add_argument("--out", default="BENCH_predict.json")
    args = ap.parse_args()
    if args.quick:
        run(P_side=5, m=8, n_queries=1000, n_train=4000, train_iters=300,
            repeats=2, out_path=args.out)
    else:
        run(out_path=args.out)


if __name__ == "__main__":
    main()
