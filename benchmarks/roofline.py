"""Roofline table (deliverable g): formats the dry-run JSONL records into
the EXPERIMENTS.md table — three terms, dominant bottleneck, MODEL_FLOPS
ratio, and a rule-based 'what would move the dominant term' note.

  PYTHONPATH=src python -m benchmarks.roofline [--jsonl dryrun_single_pod.jsonl]

If the JSONL is missing, run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_single_pod.jsonl
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def advice(rec: dict) -> str:
    dom = rec["dominant"]
    bd = rec.get("collective_breakdown", {})
    top_coll = max(bd, key=bd.get) if bd else "none"
    if dom == "collective":
        if top_coll == "all-reduce":
            return "all-reduce dominates: overlap grad reduce with bwd / reduce-scatter + fp reduced precision"
        if top_coll == "all-gather":
            return "all-gather dominates: reshard to keep the gathered operand local (check head-reshape resharding)"
        if top_coll == "all-to-all":
            return "expert all-to-all dominates: fewer expert hops (hierarchical a2a) or larger capacity batching"
        return "collective-permute bound: overlap with compute (async permute)"
    if dom == "memory":
        if rec["kind"] == "decode":
            return "KV/state streaming bound (expected at decode): quantize cache to int8 or shard seq further"
        return "HBM streaming bound: increase arithmetic intensity (fuse elementwise, larger tiles, bf16 activations)"
    return "MXU-bound: good; next lever is reducing remat recompute or attention flops (windowing)"


def load(jsonl: str) -> list:
    recs = []
    with open(jsonl) as f:
        for line in f:
            recs.append(json.loads(line))
    return recs


def fmt_table(recs: list) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | dominant "
        "| peak GiB/dev | MODEL/HLO | note |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in recs:
        t = r["roofline_s"]
        peak = r["bytes_per_device"]["peak_est"] / 2**30
        ratio = r.get("useful_compute_ratio", 0.0)
        rows.append(
            f"| {r['config_name']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute']:.3e} | {t['memory']:.3e} | {t['collective']:.3e} "
            f"| **{r['dominant']}** | {peak:.2f} | {ratio:.2f} | {advice(r)} |"
        )
    return hdr + "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="dryrun_single_pod.jsonl")
    args = ap.parse_args()
    if not os.path.exists(args.jsonl):
        print(f"{args.jsonl} not found — run the dry-run first:", file=sys.stderr)
        print("  PYTHONPATH=src python -m repro.launch.dryrun --all --out "
              + args.jsonl, file=sys.stderr)
        sys.exit(1)
    recs = load(args.jsonl)
    print(fmt_table(recs))
    for r in recs:
        t = r["roofline_s"]
        dom_val = max(t.values())
        print(f"roofline[{r['config_name']},{r['shape']}],{dom_val*1e6:.0f},"
              f"dominant={r['dominant']}")


if __name__ == "__main__":
    main()
