"""Front-door SLO benchmark — open-loop Poisson arrivals at rising QPS.

The other serving lanes (``bench_serve``) measure a single-tenant loop
handing pre-built 2048-point batches to ``Server.submit``. This lane
measures the ENDPOINT traffic shape: many concurrent clients, each
asking for 1..64 points, arriving as an OPEN-LOOP Poisson process — the
arrival schedule is fixed up front and does not slow down when the
server falls behind, so queueing delay shows up in the tail instead of
being hidden by a closed feedback loop.

Per offered-QPS level, a fresh ``api.FrontDoor`` (continuous batching:
``max_wait_ms`` window / ``max_rows`` trigger, bounded admission queue,
shed-on-full) serves the whole arrival schedule and reports end-to-end
per-request latency (p50/p95/p99, queueing included), achieved
throughput, coalescing stats (rows and requests per device batch),
recompiles (streaming q_max growth under load) and shed/delayed counts
— the tail-latency-vs-offered-load curve is the deliverable.

Golden gate (same property tests/test_frontdoor.py holds): at the lowest
level, every completed request's (mean, var) must be BITWISE equal to
serving it alone through ``Server.submit`` — coalescing is scheduling,
never math.

The record is MERGED into the bench_serve report as a ``frontdoor``
section (BENCH_serve.json by default; a fresh file is created when the
target does not exist), and ``check_bench_regression`` gates the lowest
level's p95 against benchmarks/baselines/frontdoor_smoke.json.

  PYTHONPATH=src python -m benchmarks.bench_frontdoor           # merge into BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.bench_frontdoor --quick   # CI-sized
  PYTHONPATH=src python -m benchmarks.bench_frontdoor --smoke   # seconds (the gated lane)
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import numpy as np


def _run_level(
    api, server, *, qps: float, n_req: int, seed: int, fd_config
) -> tuple[dict, list, list]:
    """One offered-load level: a seeded Poisson arrival schedule of small
    requests, all driven through one fresh FrontDoor."""
    rng = np.random.default_rng(seed)
    grid = server.fitted.grid
    lo = np.array([grid.x_edges[0], grid.y_edges[0]])
    hi = np.array([grid.x_edges[-1], grid.y_edges[-1]])
    sizes = rng.integers(1, fd_config.max_request_rows + 1, n_req)
    reqs = [rng.uniform(lo, hi, (int(s), 2)).astype(np.float32) for s in sizes]
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n_req))

    async def client(fd, i):
        await asyncio.sleep(float(arrivals[i]))
        try:
            return await fd.submit(reqs[i])
        except api.RequestRejected:
            return None

    async def drive():
        t0 = time.perf_counter()
        async with api.FrontDoor(server, fd_config) as fd:
            got = await asyncio.gather(*(client(fd, i) for i in range(n_req)))
        return got, fd.report(), time.perf_counter() - t0

    got, rep, wall = asyncio.run(drive())
    r, b = rep["requests"], rep["batches"]
    level = {
        "offered_qps": qps,
        "requests": n_req,
        "completed": r["completed"],
        "shed": r["shed"],
        "delayed": r["delayed"],
        "recompiles": rep["recompiles"],
        "batches": b["count"],
        "rows_per_batch_mean": b["rows_per_batch_mean"],
        "requests_per_batch_mean": b["requests_per_batch_mean"],
        **(rep["latency_ms"] or {}),
        "achieved_qps": r["completed"] / wall if wall > 0 else 0.0,
    }
    return level, reqs, got


def run(
    *,
    grid_side: int = 4,
    m: int = 6,
    n_train: int = 4000,
    train_iters: int = 200,
    qps_levels: tuple = (50.0, 100.0, 200.0, 400.0),
    requests_per_level: int = 120,
    mode: str = "sharded",
    router: str = "two-level",
    max_wait_ms: float = 2.0,
    max_rows: int = 1024,
    queue_depth: int = 256,
    golden_checks: int = 10,
    out_path: str = "BENCH_serve.json",
) -> dict:
    # virtual devices must be forced before any jax computation
    from repro.launch import serve_sharded as ss

    if mode == "sharded":
        ss.ensure_host_devices(grid_side * grid_side)

    import jax

    from repro import api

    print(f"# bench_frontdoor: grid={grid_side}x{grid_side} m={m} mode={mode} "
          f"router={router} levels={list(qps_levels)} "
          f"backend={jax.default_backend()}")
    ds, fitted = ss.train_demo_surface(
        seed=0, n=n_train, grid_side=grid_side, m=m, train_iters=train_iters,
    )
    serve_cfg = api.ServeConfig(
        mode=mode, pipeline="pipelined", router=router, backend="ref",
    )
    server = api.Server(fitted, serve_cfg)
    # warm the compile path with ONE tiny request — deliberately not a
    # representative batch: the streaming q_max growth (and its recompiles)
    # under rising load is part of what this lane measures
    server.submit(np.array([[ds.x[:, 0].mean(), ds.x[:, 1].mean()]], np.float32))

    fd_cfg = api.FrontDoorConfig(
        max_wait_ms=max_wait_ms, max_rows=max_rows,
        queue_depth=queue_depth, admission="shed",
    )

    levels = []
    golden = None
    for k, qps in enumerate(qps_levels):
        level, reqs, got = _run_level(
            api, server, qps=float(qps), n_req=requests_per_level,
            seed=100 + k, fd_config=fd_cfg,
        )
        levels.append(level)
        print(f"  qps={qps:>7.1f}: p95={level.get('p95_ms', float('nan')):8.2f} ms "
              f"completed={level['completed']}/{level['requests']} "
              f"shed={level['shed']} recompiles={level['recompiles']} "
              f"rows/batch={level['rows_per_batch_mean']:.1f}")
        if k == 0:
            # golden gate at the lowest level: coalesced-then-demuxed ==
            # solo Server.submit. Sharded: BITWISE (fixed-shape padded
            # program). Replicated: float32-exact — XLA re-specializes
            # per batch shape there (see repro.api.frontdoor docstring).
            strict = mode == "sharded"
            checked, ok, max_err = 0, True, 0.0
            for q, out in zip(reqs, got):
                if out is None or checked >= golden_checks:
                    continue
                ms, vs = server.submit(q)
                if strict:
                    ok = ok and np.array_equal(out[0], ms) \
                        and np.array_equal(out[1], vs)
                else:
                    err = max(float(np.abs(out[0] - ms).max()),
                              float(np.abs(out[1] - vs).max()))
                    max_err = max(max_err, err)
                    ok = ok and err <= 1e-5
                checked += 1
            golden = {
                "checked": checked, "mode": mode, "ok": bool(ok),
                "bitwise_ok": bool(ok) if strict else None,
                "max_abs_err": None if strict else max_err,
            }
            if not ok:
                raise SystemExit(
                    "GOLDEN GATE FAILED: coalesced-then-demuxed results "
                    "differ from solo Server.submit"
                )

    rec = {
        "grid": f"{grid_side}x{grid_side}",
        "m": m,
        "mode": mode,
        "router": router,
        "backend": jax.default_backend(),
        "requests_per_level": requests_per_level,
        "serve_config": serve_cfg.to_dict(),
        "frontdoor_config": fd_cfg.to_dict(),
        "fit_config": fitted.config.to_dict(),
        "levels": levels,
        "golden": golden,
        "qmax_policy": server.policy.stats() if server.policy else None,
    }

    # merge into the bench_serve report: the front door is one more lane of
    # the same serving story, not a separate artifact
    data = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data["frontdoor"] = rec
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"merged frontdoor section into {out_path}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shapes (4x4 mesh, 3 levels)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale shapes (3x3 mesh) — the regression "
                         "smoke lane (make bench-gate)")
    ap.add_argument("--mode", choices=("sharded", "replicated"),
                    default="sharded",
                    help="serve mode behind the front door (default: sharded)")
    ap.add_argument("--router", choices=("single", "two-level"),
                    default="two-level",
                    help="sharded router policy (default: two-level)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="bench_serve report to merge the frontdoor section "
                         "into (created if missing)")
    args = ap.parse_args()
    if args.smoke:
        run(grid_side=3, m=5, n_train=1200, train_iters=150,
            qps_levels=(25.0, 50.0, 100.0), requests_per_level=40,
            mode=args.mode, router=args.router, out_path=args.out)
    elif args.quick:
        run(grid_side=4, m=6, n_train=4000, train_iters=200,
            qps_levels=(50.0, 100.0, 200.0), requests_per_level=60,
            mode=args.mode, router=args.router, out_path=args.out)
    else:
        run(mode=args.mode, router=args.router, out_path=args.out)


if __name__ == "__main__":
    main()
