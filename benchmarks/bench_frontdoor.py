"""Front-door SLO benchmark — open-loop Poisson arrivals at rising QPS.

The other serving lanes (``bench_serve``) measure a single-tenant loop
handing pre-built 2048-point batches to ``Server.submit``. This lane
measures the ENDPOINT traffic shape: many concurrent clients, each
asking for 1..64 points, arriving as an OPEN-LOOP Poisson process — the
arrival schedule is fixed up front and does not slow down when the
server falls behind, so queueing delay shows up in the tail instead of
being hidden by a closed feedback loop.

Per offered-QPS level, a fresh ``api.FrontDoor`` (continuous batching:
``max_wait_ms`` window / ``max_rows`` trigger, bounded admission queue,
shed-on-full) serves the whole arrival schedule and reports end-to-end
per-request latency (p50/p95/p99, queueing included), achieved
throughput, coalescing stats (rows and requests per device batch),
recompiles (streaming q_max growth under load) and shed/delayed counts
— the tail-latency-vs-offered-load curve is the deliverable.

Golden gate (same property tests/test_frontdoor.py holds): at the lowest
level, every completed request's (mean, var) must be BITWISE equal to
serving it alone through ``Server.submit`` — coalescing is scheduling,
never math.

The record is MERGED into the bench_serve report as a ``frontdoor``
section (BENCH_serve.json by default; a fresh file is created when the
target does not exist), and ``check_bench_regression`` gates the lowest
level's p95 against benchmarks/baselines/frontdoor_smoke.json.

``--swap`` runs the HOT-SWAP lane instead (docs/lifecycle.md): one
Poisson level with a ``Server.swap`` fired from a worker thread
mid-stream — the new model's sharded cache is built and compiled while
the old one keeps serving, then goes live as one reference flip. The
lane records tail latency ACROSS the swap plus the swap wall-clock, and
its golden gate is the lifecycle property itself: every completed
answer bitwise matches exactly one of the two models, old-model answers
never follow new-model answers in service order, and nothing is shed or
corrupted. Merged as a ``frontdoor_swap`` section and gated against
benchmarks/baselines/frontdoor_swap_smoke.json.

  PYTHONPATH=src python -m benchmarks.bench_frontdoor           # merge into BENCH_serve.json
  PYTHONPATH=src python -m benchmarks.bench_frontdoor --quick   # CI-sized
  PYTHONPATH=src python -m benchmarks.bench_frontdoor --smoke   # seconds (the gated lane)
  PYTHONPATH=src python -m benchmarks.bench_frontdoor --smoke --swap  # hot-swap lane
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

import numpy as np


def _run_level(
    api, server, *, qps: float, n_req: int, seed: int, fd_config
) -> tuple[dict, list, list]:
    """One offered-load level: a seeded Poisson arrival schedule of small
    requests, all driven through one fresh FrontDoor."""
    rng = np.random.default_rng(seed)
    grid = server.fitted.grid
    lo = np.array([grid.x_edges[0], grid.y_edges[0]])
    hi = np.array([grid.x_edges[-1], grid.y_edges[-1]])
    sizes = rng.integers(1, fd_config.max_request_rows + 1, n_req)
    reqs = [rng.uniform(lo, hi, (int(s), 2)).astype(np.float32) for s in sizes]
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n_req))

    async def client(fd, i):
        await asyncio.sleep(float(arrivals[i]))
        try:
            return await fd.submit(reqs[i])
        except api.RequestRejected:
            return None

    async def drive():
        t0 = time.perf_counter()
        async with api.FrontDoor(server, fd_config) as fd:
            got = await asyncio.gather(*(client(fd, i) for i in range(n_req)))
        return got, fd.report(), time.perf_counter() - t0

    got, rep, wall = asyncio.run(drive())
    r, b = rep["requests"], rep["batches"]
    level = {
        "offered_qps": qps,
        "requests": n_req,
        "completed": r["completed"],
        "shed": r["shed"],
        "delayed": r["delayed"],
        "recompiles": rep["recompiles"],
        "batches": b["count"],
        "rows_per_batch_mean": b["rows_per_batch_mean"],
        "requests_per_batch_mean": b["requests_per_batch_mean"],
        **(rep["latency_ms"] or {}),
        "achieved_qps": r["completed"] / wall if wall > 0 else 0.0,
    }
    return level, reqs, got


def run(
    *,
    grid_side: int = 4,
    m: int = 6,
    n_train: int = 4000,
    train_iters: int = 200,
    qps_levels: tuple = (50.0, 100.0, 200.0, 400.0),
    requests_per_level: int = 120,
    mode: str = "sharded",
    router: str = "two-level",
    max_wait_ms: float = 2.0,
    max_rows: int = 1024,
    queue_depth: int = 256,
    golden_checks: int = 10,
    out_path: str = "BENCH_serve.json",
) -> dict:
    # virtual devices must be forced before any jax computation
    from repro.launch import serve_sharded as ss

    if mode == "sharded":
        ss.ensure_host_devices(grid_side * grid_side)

    import jax

    from repro import api

    print(f"# bench_frontdoor: grid={grid_side}x{grid_side} m={m} mode={mode} "
          f"router={router} levels={list(qps_levels)} "
          f"backend={jax.default_backend()}")
    ds, fitted = ss.train_demo_surface(
        seed=0, n=n_train, grid_side=grid_side, m=m, train_iters=train_iters,
    )
    serve_cfg = api.ServeConfig(
        mode=mode, pipeline="pipelined", router=router, backend="ref",
    )
    server = api.Server(fitted, serve_cfg)
    # warm the compile path with ONE tiny request — deliberately not a
    # representative batch: the streaming q_max growth (and its recompiles)
    # under rising load is part of what this lane measures
    server.submit(np.array([[ds.x[:, 0].mean(), ds.x[:, 1].mean()]], np.float32))

    fd_cfg = api.FrontDoorConfig(
        max_wait_ms=max_wait_ms, max_rows=max_rows,
        queue_depth=queue_depth, admission="shed",
    )

    levels = []
    golden = None
    for k, qps in enumerate(qps_levels):
        level, reqs, got = _run_level(
            api, server, qps=float(qps), n_req=requests_per_level,
            seed=100 + k, fd_config=fd_cfg,
        )
        levels.append(level)
        print(f"  qps={qps:>7.1f}: p95={level.get('p95_ms', float('nan')):8.2f} ms "
              f"completed={level['completed']}/{level['requests']} "
              f"shed={level['shed']} recompiles={level['recompiles']} "
              f"rows/batch={level['rows_per_batch_mean']:.1f}")
        if k == 0:
            # golden gate at the lowest level: coalesced-then-demuxed ==
            # solo Server.submit. Sharded: BITWISE (fixed-shape padded
            # program). Replicated: float32-exact — XLA re-specializes
            # per batch shape there (see repro.api.frontdoor docstring).
            strict = mode == "sharded"
            checked, ok, max_err = 0, True, 0.0
            for q, out in zip(reqs, got):
                if out is None or checked >= golden_checks:
                    continue
                ms, vs = server.submit(q)
                if strict:
                    ok = ok and np.array_equal(out[0], ms) \
                        and np.array_equal(out[1], vs)
                else:
                    err = max(float(np.abs(out[0] - ms).max()),
                              float(np.abs(out[1] - vs).max()))
                    max_err = max(max_err, err)
                    ok = ok and err <= 1e-5
                checked += 1
            golden = {
                "checked": checked, "mode": mode, "ok": bool(ok),
                "bitwise_ok": bool(ok) if strict else None,
                "max_abs_err": None if strict else max_err,
            }
            if not ok:
                raise SystemExit(
                    "GOLDEN GATE FAILED: coalesced-then-demuxed results "
                    "differ from solo Server.submit"
                )

    rec = {
        "grid": f"{grid_side}x{grid_side}",
        "m": m,
        "mode": mode,
        "router": router,
        "backend": jax.default_backend(),
        "requests_per_level": requests_per_level,
        "serve_config": serve_cfg.to_dict(),
        "frontdoor_config": fd_cfg.to_dict(),
        "fit_config": fitted.config.to_dict(),
        "levels": levels,
        "golden": golden,
        "qmax_policy": server.policy.stats() if server.policy else None,
    }

    # merge into the bench_serve report: the front door is one more lane of
    # the same serving story, not a separate artifact
    data = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data["frontdoor"] = rec
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"merged frontdoor section into {out_path}")
    return rec


def run_swap(
    *,
    grid_side: int = 4,
    m: int = 6,
    n_train: int = 4000,
    train_iters: int = 200,
    refit_iters: int = 60,
    qps: float = 100.0,
    n_req: int = 80,
    router: str = "two-level",
    max_wait_ms: float = 2.0,
    max_rows: int = 64,
    queue_depth: int = 256,
    out_path: str = "BENCH_serve.json",
) -> dict:
    """The hot-swap lane: per-request tail latency across a mid-stream
    ``Server.swap`` (docs/lifecycle.md).

    Train model A, warm-refit model B on a drifted slice, then drive one
    open-loop Poisson level through a FrontDoor and fire ``swap(B)`` from
    a worker thread once a third of the requests have completed — while
    the front door keeps admitting. The q_max high-water mark is
    pre-warmed past anything a window can need, so ONE compiled device
    shape serves both models; that is what makes the golden gate bitwise:
    every completed answer must equal serving the same request alone
    against exactly one of the two models, with the old→new transition
    monotone in service order and zero sheds (the admission queue is
    sized above the request count — any shed would be swap-attributable).
    """
    from repro.launch import serve_sharded as ss

    ss.ensure_host_devices(grid_side * grid_side)

    import jax

    from repro import api
    from repro.data.spatial import e3sm_like_field

    print(f"# bench_frontdoor --swap: grid={grid_side}x{grid_side} m={m} "
          f"router={router} qps={qps} n_req={n_req} "
          f"backend={jax.default_backend()}")
    ds, fitted = ss.train_demo_surface(
        seed=0, n=n_train, grid_side=grid_side, m=m, train_iters=train_iters,
    )
    refit_cfg = api.RefitConfig(train_iters=refit_iters)
    new = api.refit(fitted, e3sm_like_field(n=n_train, seed=1), refit_cfg)

    serve_cfg = api.ServeConfig(
        mode="sharded", pipeline="pipelined", router=router, backend="ref",
    )
    server = api.Server(fitted, serve_cfg)
    rng = np.random.default_rng(3)
    grid = fitted.grid
    lo = np.array([grid.x_edges[0], grid.y_edges[0]])
    hi = np.array([grid.x_edges[-1], grid.y_edges[-1]])
    # pre-warm the q_max high-water mark past anything a coalesced window
    # can need: one compiled shape then serves both models, the premise of
    # the bitwise classification below
    server.submit(rng.uniform(lo, hi, (max_rows * 8, 2)).astype(np.float32))
    compiles_before = server.policy.stats()["compiles"]

    fd_cfg = api.FrontDoorConfig(
        max_wait_ms=max_wait_ms, max_rows=max_rows, max_request_rows=8,
        queue_depth=queue_depth, admission="shed",
    )
    sizes = rng.integers(1, fd_cfg.max_request_rows + 1, n_req)
    reqs = [rng.uniform(lo, hi, (int(s), 2)).astype(np.float32) for s in sizes]
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n_req))
    ref_old = [server.submit(q) for q in reqs]  # active model: A

    served = []  # (request index, answer | None) in settle order
    swap_rec = {}
    # the tail of the schedule is held until the flip lands: the swap's
    # off-path build (compile included) can outlast a fast Poisson stream,
    # and the lane must always measure a POST-flip segment
    hold = max(8, n_req // 6)

    async def drive():
        loop = asyncio.get_running_loop()
        swap_done = asyncio.Event()
        state = {"completed": 0}

        async def client(fd, i):
            if i >= n_req - hold:
                await swap_done.wait()
                await asyncio.sleep(0.002 * (i - (n_req - hold)))
            else:
                await asyncio.sleep(float(arrivals[i]))
            try:
                out = await fd.submit(reqs[i])
            except api.RequestRejected:
                served.append((i, None))
                return
            state["completed"] += 1
            served.append((i, out))

        async def swapper():
            while state["completed"] < n_req // 6:
                await asyncio.sleep(0.001)
            t0 = time.perf_counter()
            rec = await loop.run_in_executor(
                None, lambda: server.swap(new, version="step-1")
            )
            swap_rec.update(rec, wall_s=time.perf_counter() - t0)
            swap_done.set()

        t0 = time.perf_counter()
        async with api.FrontDoor(server, fd_cfg) as fd:
            await asyncio.gather(swapper(), *(client(fd, i) for i in range(n_req)))
        return fd.report(), time.perf_counter() - t0

    rep, wall = asyncio.run(drive())
    ref_new = [server.submit(q) for q in reqs]  # active model: B

    labels = []
    for i, out in served:
        if out is None:
            labels.append("shed")
        elif np.array_equal(out[0], ref_old[i][0]) \
                and np.array_equal(out[1], ref_old[i][1]):
            labels.append("old")
        elif np.array_equal(out[0], ref_new[i][0]) \
                and np.array_equal(out[1], ref_new[i][1]):
            labels.append("new")
        else:
            labels.append("corrupt")
    answered = [lab for lab in labels if lab != "shed"]
    monotone = "old" not in answered[answered.index("new"):] \
        if "new" in answered else True
    shape_stable = server.policy.stats()["compiles"] == compiles_before
    ok = (
        shape_stable and monotone
        and "corrupt" not in labels and "shed" not in labels
        and "old" in answered and "new" in answered
    )
    golden = {
        "mode": "sharded", "ok": bool(ok), "bitwise_ok": "corrupt" not in labels,
        "monotone": bool(monotone), "shape_stable": bool(shape_stable),
        "pre_flip": answered.count("old"), "post_flip": answered.count("new"),
        "shed": labels.count("shed"), "corrupt": labels.count("corrupt"),
    }
    if not ok:
        raise SystemExit(f"SWAP GOLDEN GATE FAILED: {golden}")

    r, b = rep["requests"], rep["batches"]
    level = {
        "offered_qps": qps,
        "requests": n_req,
        "completed": r["completed"],
        "shed": r["shed"],
        "delayed": r["delayed"],
        "recompiles": rep["recompiles"],
        "batches": b["count"],
        "rows_per_batch_mean": b["rows_per_batch_mean"],
        "requests_per_batch_mean": b["requests_per_batch_mean"],
        **(rep["latency_ms"] or {}),
        "achieved_qps": r["completed"] / wall if wall > 0 else 0.0,
    }
    print(f"  qps={qps:>7.1f}: p95={level.get('p95_ms', float('nan')):8.2f} ms "
          f"across the swap | pre-flip={golden['pre_flip']} "
          f"post-flip={golden['post_flip']} shed={golden['shed']} | "
          f"swap build {swap_rec.get('build_s', float('nan')):.2f}s")

    rec = {
        "grid": f"{grid_side}x{grid_side}",
        "m": m,
        "mode": "sharded",
        "router": router,
        "backend": jax.default_backend(),
        "requests_per_level": n_req,
        "serve_config": serve_cfg.to_dict(),
        "frontdoor_config": fd_cfg.to_dict(),
        "fit_config": fitted.config.to_dict(),
        "refit_config": refit_cfg.to_dict(),
        "levels": [level],
        "golden": golden,
        "swap": {**swap_rec, "refit_s": new.refit_seconds,
                 "lifecycle": rep["lifecycle"]},
        "qmax_policy": server.policy.stats() if server.policy else None,
    }

    data = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
    data["frontdoor_swap"] = rec
    print(json.dumps(rec, indent=2))
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
    print(f"merged frontdoor_swap section into {out_path}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized shapes (4x4 mesh, 3 levels)")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale shapes (3x3 mesh) — the regression "
                         "smoke lane (make bench-gate)")
    ap.add_argument("--mode", choices=("sharded", "replicated"),
                    default="sharded",
                    help="serve mode behind the front door (default: sharded)")
    ap.add_argument("--router", choices=("single", "two-level"),
                    default="two-level",
                    help="sharded router policy (default: two-level)")
    ap.add_argument("--swap", action="store_true",
                    help="run the hot-swap lane instead: tail latency across "
                         "a mid-stream Server.swap (frontdoor_swap section)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="bench_serve report to merge the frontdoor section "
                         "into (created if missing)")
    args = ap.parse_args()
    if args.swap:
        if args.mode != "sharded":
            ap.error("--swap is the sharded lane (the replicated path is "
                     "covered in tests/test_lifecycle.py)")
        if args.smoke:
            run_swap(grid_side=3, m=5, n_train=1200, train_iters=150,
                     refit_iters=50, qps=100.0, n_req=60,
                     router=args.router, out_path=args.out)
        elif args.quick:
            run_swap(grid_side=4, m=6, n_train=4000, train_iters=200,
                     refit_iters=60, qps=100.0, n_req=80,
                     router=args.router, out_path=args.out)
        else:
            run_swap(qps=150.0, n_req=150, router=args.router,
                     out_path=args.out)
        return
    if args.smoke:
        run(grid_side=3, m=5, n_train=1200, train_iters=150,
            qps_levels=(25.0, 50.0, 100.0), requests_per_level=40,
            mode=args.mode, router=args.router, out_path=args.out)
    elif args.quick:
        run(grid_side=4, m=6, n_train=4000, train_iters=200,
            qps_levels=(50.0, 100.0, 200.0), requests_per_level=60,
            mode=args.mode, router=args.router, out_path=args.out)
    else:
        run(mode=args.mode, router=args.router, out_path=args.out)


if __name__ == "__main__":
    main()
